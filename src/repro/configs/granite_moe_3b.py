"""Granite-MoE 3B-a800m — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    pattern=("attn",), rope_theta=1e4,
    norm="rms", gated_mlp=True, act="silu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8),
    skip_shapes=(("long_500k", "pure full-attention arch"),),
)
