"""Pallas TPU compound kernel: one fused dycore field step per grid cell.

This is the NERO dataflow argument (arxiv 2107.08716 §3) applied to the whole
dycore step instead of a single stencil: the CPU/GPU baseline writes every
stage's result back to main memory (vadvc tendency, explicitly-updated field,
padded halo copy), while the FPGA PE streams a window once and pipelines
laplace -> flux-limit -> output plus the vertical Thomas solve entirely in
near-memory (BRAM/URAM).  The TPU formulation of that PE:

  * grid = (batch, ny/ty): each grid cell owns a full z-slab of one y-window
    (vadvc is sequential in z, so z is never tiled — the paper's PE design);
    batch rides the ensemble axis.
  * The 2-deep periodic y-halo is realized with three aliased input refs
    (prev / cur / next window) whose index maps wrap modulo the window count
    — the overlapping-window idiom from kernels/hdiff/hdiff.py, made
    periodic.  x stays whole inside the window; the periodic x-halo is a
    lane roll in VMEM.
  * Stages chain through VMEM scratch only: the forward Thomas sweep stores
    (ccol, dcol) in fp32 scratch (the paper's "intermediate buffer to allow
    for backward sweep calculation"), backward substitution writes the stage
    tendency into scratch, the point-wise update and the compound hdiff read
    it straight from VMEM, and only (f_new, stage) for the *cur* window ever
    travel back to HBM.
  * Compute is fp32 internally; bf16 I/O supported (the paper's
    half-precision mode trades HBM traffic for accuracy).

The staggered vertical velocity enters pre-combined: callers pass
w = wcon_i + wcon_{i+1} (periodic next column), which is the only combination
the solve ever uses — this keeps every block transfer a clean rectangular
HBM->VMEM DMA, the same trick vadvc.py uses with its wl/wr pre-slices.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.kernels.hdiff.ref import DEFAULT_COEFF
from repro.kernels.vadvc.ref import BET_M, BET_P, DTR_STAGE

HALO = 2   # y/x halo depth of the compound hdiff stage


def _window_step(fwork, wwork, rhs, ccol, dcol, stage,
                 *, nz: int, dt: float, coeff: float):
    """One full dycore step on the (nz, R, nx) fp32 working window held in
    VMEM scratch refs: Thomas solve -> stage tendency (written into `stage`),
    point-wise update, compound hdiff with periodic in-window wrap in both y
    and x.  Returns the diffused field as a (nz, R, nx) array; rows within
    HALO of a window edge whose wrap is not truly periodic come out garbage
    (callers crop / shrink validity accordingly)."""

    def ld(ref, k):
        return ref[pl.ds(k, 1)][0]

    # ---- vadvc forward sweep, k = 0 ---------------------------------------
    gcv = 0.25 * ld(wwork, 1)
    cs = gcv * BET_M
    ccol0 = gcv * BET_P
    bcol = DTR_STAGE - ccol0
    corr = -cs * (ld(fwork, 1) - ld(fwork, 0))
    divided = 1.0 / bcol
    ccol[pl.ds(0, 1)] = (ccol0 * divided)[None]
    dcol[pl.ds(0, 1)] = ((ld(rhs, 0) + corr) * divided)[None]

    # ---- forward sweep, 0 < k < nz-1 --------------------------------------
    def fwd_body(k, _):
        gav = -0.25 * ld(wwork, k)
        gcv = 0.25 * ld(wwork, k + 1)
        as_ = gav * BET_M
        cs = gcv * BET_M
        acol = gav * BET_P
        ccol_k = gcv * BET_P
        bcol = DTR_STAGE - acol - ccol_k
        fk = ld(fwork, k)
        corr = (-as_ * (ld(fwork, k - 1) - fk)
                - cs * (ld(fwork, k + 1) - fk))
        cprev = ccol[pl.ds(k - 1, 1)][0]
        dprev = dcol[pl.ds(k - 1, 1)][0]
        divided = 1.0 / (bcol - cprev * acol)
        ccol[pl.ds(k, 1)] = (ccol_k * divided)[None]
        dcol[pl.ds(k, 1)] = (((ld(rhs, k) + corr) - dprev * acol)
                             * divided)[None]
        return 0

    jax.lax.fori_loop(1, nz - 1, fwd_body, 0)

    # ---- forward sweep, k = nz-1 ------------------------------------------
    k = nz - 1
    gav = -0.25 * ld(wwork, k)
    as_ = gav * BET_M
    acol = gav * BET_P
    bcol = DTR_STAGE - acol
    corr = -as_ * (ld(fwork, k - 1) - ld(fwork, k))
    cprev = ccol[pl.ds(k - 1, 1)][0]
    dprev = dcol[pl.ds(k - 1, 1)][0]
    divided = 1.0 / (bcol - cprev * acol)
    dlast = ((ld(rhs, k) + corr) - dprev * acol) * divided
    dcol[pl.ds(k, 1)] = dlast[None]

    # ---- backward substitution -> stage tendency, never leaving VMEM -------
    stage[pl.ds(nz - 1, 1)] = (DTR_STAGE * (dlast - ld(fwork, nz - 1)))[None]

    def bwd_body(m, datac):
        k = nz - 2 - m
        datac = dcol[pl.ds(k, 1)][0] - ccol[pl.ds(k, 1)][0] * datac
        stage[pl.ds(k, 1)] = (DTR_STAGE * (datac - ld(fwork, k)))[None]
        return datac

    jax.lax.fori_loop(0, nz - 1, bwd_body, dlast)

    # ---- point-wise explicit update (still in VMEM) ------------------------
    fup = fwork[...] + dt * stage[...]

    # ---- compound hdiff on the updated field -------------------------------
    # Both y and x shifts are periodic VMEM rolls over the working window;
    # at window edges whose wrap is not truly periodic this writes garbage
    # that stays within HALO rows/cols of the edge.
    def s(dj: int, di: int) -> jnp.ndarray:
        win = jnp.roll(fup, -dj, axis=1) if dj else fup
        return jnp.roll(win, -di, axis=2) if di else win

    def lap(dj: int, di: int) -> jnp.ndarray:
        # true-Laplacian sign (see kernels/hdiff/ref.py)
        return ((s(dj, di - 1) + s(dj, di + 1)
                 + s(dj - 1, di) + s(dj + 1, di))
                - 4.0 * s(dj, di))

    lap_c, lap_xp, lap_xm = lap(0, 0), lap(0, 1), lap(0, -1)
    lap_yp, lap_ym = lap(1, 0), lap(-1, 0)

    flx = lap_xp - lap_c
    flx_m = lap_c - lap_xm
    fly = lap_yp - lap_c
    fly_m = lap_c - lap_ym
    # COSMO flux limiter.
    flx = jnp.where(flx * (s(0, 1) - s(0, 0)) > 0.0, 0.0, flx)
    flx_m = jnp.where(flx_m * (s(0, 0) - s(0, -1)) > 0.0, 0.0, flx_m)
    fly = jnp.where(fly * (s(1, 0) - s(0, 0)) > 0.0, 0.0, fly)
    fly_m = jnp.where(fly_m * (s(0, 0) - s(-1, 0)) > 0.0, 0.0, fly_m)

    return s(0, 0) - coeff * ((flx - flx_m) + (fly - fly_m))


def _fused_kernel(f_prev, f_cur, f_next,
                  w_prev, w_cur, w_next,
                  t_prev, t_cur, t_next,
                  s_prev, s_cur, s_next,
                  outf_ref, outs_ref,
                  fwork, wwork, rhs, ccol, dcol, stage,
                  *, nz: int, ty: int, dt: float, coeff: float):
    f32 = jnp.float32

    def asm(prev, cur, nxt):
        """Assemble the (nz, ty+4, nx) fp32 working window: cur plus a 2-row
        halo taken from the periodic prev/next windows."""
        return jnp.concatenate(
            [prev[0][:, -HALO:], cur[0], nxt[0][:, :HALO]],
            axis=1).astype(f32)

    fwork[...] = asm(f_prev, f_cur, f_next)
    wwork[...] = asm(w_prev, w_cur, w_next)
    # u_pos == u_stage == f in the dycore step, so the static part of the
    # tridiagonal RHS is precomputed once per window.
    rhs[...] = (DTR_STAGE * fwork[...] + asm(t_prev, t_cur, t_next)
                + asm(s_prev, s_cur, s_next))

    out = _window_step(fwork, wwork, rhs, ccol, dcol, stage,
                       nz=nz, dt=dt, coeff=coeff)
    outf_ref[0] = out[:, HALO:HALO + ty, :].astype(outf_ref.dtype)
    outs_ref[0] = stage[:, HALO:HALO + ty, :].astype(outs_ref.dtype)


def fused_dycore_pallas(f: jnp.ndarray, w: jnp.ndarray, utens: jnp.ndarray,
                        utens_stage: jnp.ndarray, *,
                        coeff: float = DEFAULT_COEFF, dt: float = 0.1,
                        ty: int = 8, interpret: bool = False):
    """Fused dycore field step.  All inputs (..., nz, ny, nx), doubly
    periodic in (y, x); `w` is the pre-combined staggered vertical velocity
    wcon_i + wcon_{i+1} (see module docstring).  ny % ty == 0, ty >= 2,
    nz >= 2.  Returns (f_new, stage) shaped/typed like `f`.
    """
    shape = f.shape
    nz, ny, nx = shape[-3:]
    if ny % ty or ty < 2:
        raise ValueError(f"ny={ny} must be divisible by ty={ty} >= 2")
    if nz < 2:
        raise ValueError(f"nz={nz} must be >= 2 (staggered vertical sweep)")
    nyb = ny // ty
    batch = math.prod(shape[:-3]) if len(shape) > 3 else 1

    spec = functools.partial(pl.BlockSpec, (1, nz, ty, nx))
    # Periodic overlapping windows: prev/next wrap modulo the window count.
    window = [
        spec(lambda b, j: (b, 0, (j + nyb - 1) % nyb, 0)),   # prev
        spec(lambda b, j: (b, 0, j, 0)),                     # cur
        spec(lambda b, j: (b, 0, (j + 1) % nyb, 0)),         # next
    ]
    out_spec = spec(lambda b, j: (b, 0, j, 0))

    kernel = functools.partial(_fused_kernel, nz=nz, ty=ty, dt=dt,
                               coeff=coeff)
    bshape = (batch, nz, ny, nx)
    scratch = pltpu.VMEM((nz, ty + 2 * HALO, nx), jnp.float32)
    fn = pl.pallas_call(
        kernel,
        grid=(batch, nyb),
        in_specs=window * 4,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct(bshape, f.dtype)] * 2,
        scratch_shapes=[scratch] * 6,   # fwork, wwork, rhs, ccol, dcol, stage
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_dycore_fused",
    )
    args = []
    for a in (f, w, utens, utens_stage):
        a = a.reshape(bshape)
        args += [a, a, a]
    f_new, stage = fn(*args)
    return f_new.reshape(shape), stage.reshape(shape)


class _StackedLayout:
    """Validated geometry + BlockSpec pieces of the (batch, ny/ty, field)
    grid shared by the whole-state and k-step wrappers: per-field operands
    flattened to `batch*nf` with periodic prev/cur/next y-windows, the
    shared `w` keeping its un-stacked layout and a field-collapsing index
    map."""

    def __init__(self, fs: jnp.ndarray, w: jnp.ndarray, ty: int):
        shape = fs.shape
        if len(shape) < 4:
            raise ValueError(f"fs must be (..., nf, nz, ny, nx), got {shape}")
        nf, nz, ny, nx = shape[-4:]
        if ny % ty or ty < 2:
            raise ValueError(f"ny={ny} must be divisible by ty={ty} >= 2")
        if nz < 2:
            raise ValueError(f"nz={nz} must be >= 2 (staggered vertical "
                             f"sweep)")
        if w.shape[-3:] != (nz, ny, nx):
            raise ValueError(f"w shape {w.shape} != fields grid "
                             f"{(nz, ny, nx)}")
        self.nf, self.nz, self.ny, self.nx = nf, nz, ny, nx
        self.nyb = ny // ty
        batch = math.prod(shape[:-4]) if len(shape) > 4 else 1
        self.batch = batch
        self.grid = (batch, self.nyb, nf)
        self.fshape = (batch * nf, nz, ny, nx)
        self.wshape = (batch, nz, ny, nx)
        spec = functools.partial(pl.BlockSpec, (1, nz, ty, nx))
        nyb = self.nyb

        def fmap(dj: int):
            return lambda b, j, k: (b * nf + k, 0, (j + dj) % nyb, 0)

        def wmap(dj: int):
            # Shared operand: the field grid index k is collapsed — the
            # block index repeats across the nf innermost iterations, so
            # the slab is fetched once per (b, j).
            return lambda b, j, k: (b, 0, (j + dj) % nyb, 0)

        self.fwin = [spec(fmap(nyb - 1)), spec(fmap(0)), spec(fmap(1))]
        self.wwin = [spec(wmap(nyb - 1)), spec(wmap(0)), spec(wmap(1))]
        self.out_spec = spec(lambda b, j, k: (b * nf + k, 0, j, 0))


def fused_dycore_whole_state_pallas(fs: jnp.ndarray, w: jnp.ndarray,
                                    utens: jnp.ndarray,
                                    utens_stage: jnp.ndarray, *,
                                    coeff: float = DEFAULT_COEFF,
                                    dt: float = 0.1, ty: int = 8,
                                    interpret: bool = False):
    """Whole-state fused dycore step: ONE `pallas_call` for every prognostic
    field, sharing the staggered-velocity slab across fields.

    `fs`, `utens`, `utens_stage` are field-stacked `(..., nf, nz, ny, nx)`;
    `w` is the pre-combined staggered vertical velocity `(..., nz, ny, nx)`,
    identical for every field.  The grid is `(batch, ny/ty, nf)` with the
    field axis innermost and the per-field operands flattened to
    `batch*nf` so their index maps read `b*nf + k` — while `w` keeps its
    un-stacked layout and an index map that *ignores* `k`.  Consecutive
    field iterations therefore revisit the same `w` block index, and Pallas
    elides the re-fetch: each (ensemble, y-window) slab of `w` is DMA'd
    from HBM once per step instead of once per field (~1/(3+1/nf) of input
    traffic saved, 25% at nf→∞) on top of the nf× launch amortization.

    Returns `(f_new, stage)` shaped/typed like `fs`.
    """
    shape = fs.shape
    lay = _StackedLayout(fs, w, ty)

    kernel = functools.partial(_fused_kernel, nz=lay.nz, ty=ty, dt=dt,
                               coeff=coeff)
    scratch = pltpu.VMEM((lay.nz, ty + 2 * HALO, lay.nx), jnp.float32)
    fn = pl.pallas_call(
        kernel,
        grid=lay.grid,
        in_specs=lay.fwin + lay.wwin + lay.fwin + lay.fwin,
        out_specs=[lay.out_spec, lay.out_spec],
        out_shape=[jax.ShapeDtypeStruct(lay.fshape, fs.dtype)] * 2,
        scratch_shapes=[scratch] * 6,   # fwork, wwork, rhs, ccol, dcol, stage
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="nero_dycore_whole_state",
    )
    args = []
    for a, s in ((fs, lay.fshape), (w, lay.wshape), (utens, lay.fshape),
                 (utens_stage, lay.fshape)):
        a = a.reshape(s)
        args += [a, a, a]
    f_new, stage = fn(*args)
    return f_new.reshape(shape), stage.reshape(shape)


# ---------------------------------------------------------------------------
# k-step kernel: the whole communication-avoiding round in ONE launch.
# ---------------------------------------------------------------------------

def _kstep_body(outf_ref, outs_ref,
                fwork, wwork, twork, swork, rhs, ccol, dcol, stage,
                *, nz: int, ty: int, k_steps: int, dt: float, coeff: float):
    """Run the k-step time loop on the (nz, 3*ty, nx) working window already
    assembled into scratch.  Prognostic state (field + stage tendency) lives
    in `fwork`/`swork` between local steps — it never round-trips HBM.  Each
    step's in-window wrap garbage advances HALO rows per step from the window
    edges; `ty >= k_steps*HALO` keeps the central `ty` rows valid."""

    def body(_, carry):
        # u_pos == u_stage == f; the tridiagonal RHS is rebuilt each step
        # from the carried state and the constant slow tendency.
        rhs[...] = DTR_STAGE * fwork[...] + twork[...] + swork[...]
        out = _window_step(fwork, wwork, rhs, ccol, dcol, stage,
                           nz=nz, dt=dt, coeff=coeff)
        fwork[...] = out
        swork[...] = stage[...]
        return carry

    jax.lax.fori_loop(0, k_steps, body, 0)
    outf_ref[0] = fwork[:, ty:2 * ty, :].astype(outf_ref.dtype)
    outs_ref[0] = swork[:, ty:2 * ty, :].astype(outs_ref.dtype)


def _asm_full(prev, cur, nxt, dtype=jnp.float32):
    """Assemble the full (nz, 3*ty, nx) working window from three whole
    aliased windows (the k-step halo is up to ty deep per side)."""
    return jnp.concatenate([prev[0], cur[0], nxt[0]], axis=1).astype(dtype)


def _kstep_kernel_windows(f_prev, f_cur, f_next,
                          w_prev, w_cur, w_next,
                          t_prev, t_cur, t_next,
                          s_prev, s_cur, s_next,
                          outf_ref, outs_ref,
                          fwork, wwork, twork, swork, rhs, ccol, dcol, stage,
                          *, nz: int, ty: int, k_steps: int, dt: float,
                          coeff: float):
    """Interpreter-safe k-step kernel: `w` arrives as three aliased BlockSpec
    windows (index map collapses the field axis, so Pallas elides the
    re-fetch across the nf innermost iterations)."""
    fwork[...] = _asm_full(f_prev, f_cur, f_next)
    wwork[...] = _asm_full(w_prev, w_cur, w_next)
    twork[...] = _asm_full(t_prev, t_cur, t_next)
    swork[...] = _asm_full(s_prev, s_cur, s_next)
    _kstep_body(outf_ref, outs_ref, fwork, wwork, twork, swork, rhs, ccol,
                dcol, stage, nz=nz, ty=ty, k_steps=k_steps, dt=dt,
                coeff=coeff)


def _kstep_kernel_prefetch(f_prev, f_cur, f_next,
                           w_hbm,
                           t_prev, t_cur, t_next,
                           s_prev, s_cur, s_next,
                           outf_ref, outs_ref,
                           fwork, wwork, twork, swork, rhs, ccol, dcol,
                           stage, wbuf, wsem,
                           *, nz: int, ty: int, k_steps: int, dt: float,
                           coeff: float, nyb: int):
    """k-step kernel with explicit double-buffered `w` prefetch: `w` stays in
    HBM (`memory_space=ANY`) and is DMA'd by hand with `make_async_copy`.
    While window j iterates its nf fields and k local steps, window j+1's
    three `w` sections are already in flight into the other buffer slot, so
    the shared-slab fetch overlaps compute instead of serializing at the
    window boundary."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    kf = pl.program_id(2)

    def dma(slot, jj, sec):
        # Section `sec` (0/1/2 = prev/cur/next) of window jj, periodic in y.
        row = jax.lax.rem(jj + (sec - 1) + nyb, nyb) * ty
        return pltpu.make_async_copy(
            w_hbm.at[b, :, pl.ds(row, ty), :],
            wbuf.at[slot, :, pl.ds(sec * ty, ty), :],
            wsem.at[slot, sec])

    slot = jax.lax.rem(j, 2)

    @pl.when(kf == 0)
    def _fetch():
        # Warm-up: the first window of each batch row starts its own copies
        # (nothing was in flight for it).
        @pl.when(j == 0)
        def _warm():
            for sec in range(3):
                dma(0, 0, sec).start()
        for sec in range(3):
            dma(slot, j, sec).wait()
        # Prefetch the NEXT window's w into the other slot while this
        # window's nf fields x k steps compute.
        @pl.when(j + 1 < nyb)
        def _ahead():
            for sec in range(3):
                dma(jax.lax.rem(j + 1, 2), j + 1, sec).start()
        wwork[...] = wbuf[slot].astype(jnp.float32)

    fwork[...] = _asm_full(f_prev, f_cur, f_next)
    twork[...] = _asm_full(t_prev, t_cur, t_next)
    swork[...] = _asm_full(s_prev, s_cur, s_next)
    _kstep_body(outf_ref, outs_ref, fwork, wwork, twork, swork, rhs, ccol,
                dcol, stage, nz=nz, ty=ty, k_steps=k_steps, dt=dt,
                coeff=coeff)


def fused_dycore_kstep_pallas(fs: jnp.ndarray, w: jnp.ndarray,
                              utens: jnp.ndarray, utens_stage: jnp.ndarray,
                              *, k_steps: int, coeff: float = DEFAULT_COEFF,
                              dt: float = 0.1, ty: int = 8,
                              interpret: bool = False,
                              prefetch_w: bool | None = None):
    """The whole communication-avoiding round in ONE `pallas_call`: grid
    `(ensemble, ny/ty, field)`, and the kernel body runs the `k_steps` time
    loop internally (`lax.fori_loop` over Thomas solve + update + hdiff),
    so the prognostic state between local steps lives in VMEM scratch
    instead of round-tripping HBM k times.

    Shapes as `fused_dycore_whole_state_pallas`: `fs`/`utens`/`utens_stage`
    field-stacked `(..., nf, nz, ny, nx)`, shared staggered velocity `w`
    `(..., nz, ny, nx)`, doubly periodic in (y, x).  Each grid cell stages a
    3-window (`3*ty`-row) y-slab and shrinks its valid region by HALO per
    local step, so `ty >= k_steps * HALO` is required (the redundant
    halo-ring flops are the communication-avoiding price).

    `prefetch_w=True` (default outside interpret mode) streams the shared
    `w` slab with an explicit double-buffered `pltpu.make_async_copy`
    pipeline: window j+1's slab is DMA'd while window j computes.
    `prefetch_w=False` is the interpreter-safe fallback (three aliased
    BlockSpec windows with a field-collapsing index map, fetch elided
    across the field axis).  Both paths are bit-identical.

    Returns `(f_new, stage)` shaped/typed like `fs` — the state after
    `k_steps` timesteps and the last step's stage tendency.
    """
    shape = fs.shape
    if k_steps < 1:
        raise ValueError(f"k_steps={k_steps} must be >= 1")
    lay = _StackedLayout(fs, w, ty)
    if ty < k_steps * HALO:
        raise ValueError(
            f"ty={ty} must be >= k_steps*HALO={k_steps * HALO}: each local "
            f"step consumes a {HALO}-row ring of window validity")
    if prefetch_w is None:
        prefetch_w = not interpret
    nz, nx = lay.nz, lay.nx

    window = pltpu.VMEM((nz, 3 * ty, nx), jnp.float32)
    # fwork, wwork, twork, swork, rhs, ccol, dcol, stage
    scratch = [window] * 8
    if prefetch_w:
        kernel = functools.partial(_kstep_kernel_prefetch, nz=nz, ty=ty,
                                   k_steps=k_steps, dt=dt, coeff=coeff,
                                   nyb=lay.nyb)
        wspec = [pl.BlockSpec(memory_space=pltpu.ANY)]
        scratch = scratch + [pltpu.VMEM((2, nz, 3 * ty, nx), w.dtype),
                             pltpu.SemaphoreType.DMA((2, 3))]
    else:
        kernel = functools.partial(_kstep_kernel_windows, nz=nz, ty=ty,
                                   k_steps=k_steps, dt=dt, coeff=coeff)
        wspec = lay.wwin

    fn = pl.pallas_call(
        kernel,
        grid=lay.grid,
        in_specs=lay.fwin + wspec + lay.fwin + lay.fwin,
        out_specs=[lay.out_spec, lay.out_spec],
        out_shape=[jax.ShapeDtypeStruct(lay.fshape, fs.dtype)] * 2,
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="nero_dycore_kstep",
    )
    args = [a.reshape(lay.fshape) for a in (fs, fs, fs)]
    wa = w.reshape(lay.wshape)
    args += [wa] if prefetch_w else [wa, wa, wa]
    for a in (utens, utens_stage):
        a = a.reshape(lay.fshape)
        args += [a, a, a]
    f_new, stage = fn(*args)
    return f_new.reshape(shape), stage.reshape(shape)
