"""Decoder-only LM assembled from blocks, with scan-over-superblocks.

Layer heterogeneity (gemma3 5:1 local:global, recurrentgemma 2:1) is handled
by scanning one *pattern period* (super-block) per step over stacked params —
keeps HLO size O(pattern), mandatory at 512 devices — plus an explicit
remainder.  Caches are stacked the same way and threaded through the scan.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.common import embed_init, norm_apply, norm_init
from repro.parallel import policy


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4 + cfg.n_remainder)

    def superblock(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return {f"b{i}": B.block_init(kind, kk[i], cfg, dtype)
                for i, kind in enumerate(cfg.pattern)}

    stacked = jax.vmap(superblock)(
        jax.random.split(ks[0], cfg.n_repeats))
    params = {
        "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
        "superblocks": stacked,
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    for r in range(cfg.n_remainder):
        kind = cfg.pattern[r]
        params[f"rem{r}"] = B.block_init(kind, ks[4 + r], cfg, dtype)
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[2], cfg.padded_vocab, cfg.d_model,
                                    dtype).T
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)

    def superblock_cache(_):
        return {f"b{i}": B.init_block_cache(kind, cfg, batch, max_len, dtype)
                for i, kind in enumerate(cfg.pattern)}

    stacked = jax.vmap(superblock_cache)(jnp.arange(cfg.n_repeats))
    cache = {"superblocks": stacked}
    for r in range(cfg.n_remainder):
        cache[f"rem{r}"] = B.init_block_cache(cfg.pattern[r], cfg, batch,
                                              max_len, dtype)
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _positions(cfg: ModelConfig, b: int, t: int, offset) -> jnp.ndarray:
    pos = offset + jnp.arange(t)
    pos = jnp.broadcast_to(pos, (b, t))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (b, t, 3))
    return pos


def apply(cfg: ModelConfig, params, tokens=None, *, mode: str = "train",
          cache=None, pos=0, embeddings=None, remat: str = "full",
          scan_unroll: bool = False, return_hidden: bool = False):
    """Forward pass.

    tokens: (B, T) int32, or `embeddings`: (B, T, D) (modality stubs).
    mode "train": logits only.  "prefill": logits + filled cache.
    "decode": T == 1, reads/writes cache at `pos`.
    `scan_unroll` unrolls the layer scan (dry-run cost-analysis accuracy:
    XLA while-loop bodies are cost-counted once, so the roofline pass
    compiles unrolled).  `return_hidden` skips the LM head (the chunked
    cross-entropy computes it windowed — never materializing (B,T,V)).
    Returns (logits_or_hidden, new_cache, aux_loss).
    """
    if embeddings is None:
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    else:
        x = embeddings.astype(jnp.dtype(cfg.dtype))
    x = policy.batch_only(x)
    b, t = x.shape[:2]
    positions = _positions(cfg, b, t, pos if mode == "decode" else 0)

    def superblock_body(carry, xs):
        xcur, aux = carry
        p_sb, c_sb = xs
        xcur = policy.carry(xcur)
        p_sb = policy.gather_block_weights(p_sb)
        new_c = {}
        for i, kind in enumerate(cfg.pattern):
            c_i = None if c_sb is None else c_sb[f"b{i}"]
            xcur, nc, a = B.block_apply(kind, cfg, p_sb[f"b{i}"], xcur,
                                        positions=positions, mode=mode,
                                        cache=c_i, pos=pos)
            new_c[f"b{i}"] = nc if nc is not None else jnp.zeros((), x.dtype)
            aux = aux + a
        return (xcur, aux), new_c

    body = superblock_body
    if mode == "train" and remat != "none":
        ckpt_policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if remat == "dots" else None)
        body = jax.checkpoint(superblock_body, policy=ckpt_policy,
                              prevent_cse=False)

    sb_cache = cache["superblocks"] if cache is not None else None
    if sb_cache is None:
        # dummy per-repeat cache so scan xs have a leading axis
        sb_cache = jax.tree.map(
            lambda _: jnp.zeros((cfg.n_repeats,), jnp.float32),
            {f"b{i}": 0.0 for i in range(len(cfg.pattern))})
    (x, aux), new_sb_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["superblocks"], sb_cache),
        unroll=cfg.n_repeats if scan_unroll else 1)

    new_cache = {"superblocks": new_sb_cache} if cache is not None else None
    for r in range(cfg.n_remainder):
        kind = cfg.pattern[r]
        c_r = cache.get(f"rem{r}") if cache is not None else None
        x = policy.carry(x)
        x, nc, a = B.block_apply(kind, cfg,
                                 policy.gather_block_weights(
                                     params[f"rem{r}"]), x,
                                 positions=positions, mode=mode,
                                 cache=c_r, pos=pos)
        aux = aux + a
        if cache is not None:
            new_cache[f"rem{r}"] = nc

    x = norm_apply(cfg, params["final_norm"], x)
    if return_hidden:
        return x, new_cache, aux
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(x.dtype)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    logits = mask_padded_vocab(logits, cfg.vocab_size)
    return logits, new_cache, aux


def mask_padded_vocab(logits: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """-inf out the physical padding columns (padded_vocab > vocab_size) so
    sampling / logsumexp never see them."""
    pv = logits.shape[-1]
    if pv == vocab:
        return logits
    valid = jnp.arange(pv) < vocab
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def chunked_xent(hidden, head, targets, chunk: int = 512,
                 softcap: float = 0.0, unroll: bool = False,
                 vocab: int = 0):
    """Next-token NLL without materializing (B, T, V): scan over sequence
    windows of the hidden states (the NERO tiling discipline applied to the
    LM head).  hidden: (B, T, D); targets: (B, T) aligned with hidden.
    `vocab`: logical vocab size (masks physical padding columns)."""
    b, t, d = hidden.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nchunks = hidden.shape[1] // chunk
    hs = hidden.reshape(b, nchunks, chunk, d).swapaxes(0, 1)
    ts = targets.reshape(b, nchunks, chunk).swapaxes(0, 1)
    valid_len = t

    def body(acc, xs):
        i, h_c, t_c = xs
        h_c = policy.batch_only(h_c)
        lg = (h_c @ head.astype(h_c.dtype)).astype(jnp.float32)
        lg = policy.batch_model_last(lg)
        if softcap:
            lg = jnp.tanh(lg / softcap) * softcap
        if vocab:
            lg = mask_padded_vocab(lg, vocab)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, t_c[..., None], axis=-1)[..., 0]
        posn = i * chunk + jnp.arange(chunk)
        mask = (posn < valid_len).astype(jnp.float32)
        return acc + ((logz - gold) * mask).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (jnp.arange(nchunks), hs, ts),
                            unroll=nchunks if unroll else 1)
    return total / (b * valid_len)


def loss_fn(cfg: ModelConfig, params, batch, remat: str = "full",
            scan_unroll: bool = False, xent_chunk: int = 512):
    """Next-token cross-entropy (+ MoE aux).  batch: {"tokens": (B, T)}."""
    tokens = batch["tokens"]
    hidden, _, aux = apply(cfg, params, tokens, mode="train", remat=remat,
                           scan_unroll=scan_unroll, return_hidden=True)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    nll = chunked_xent(hidden[:, :-1], head, tokens[:, 1:],
                       chunk=xent_chunk, softcap=cfg.logit_softcap,
                       unroll=scan_unroll, vocab=cfg.vocab_size)
    if cfg.moe:
        nll = nll + cfg.moe.aux_loss_weight * aux
    return nll


def prefill(cfg: ModelConfig, params, tokens, max_len: Optional[int] = None,
            scan_unroll: bool = False):
    """Run the prompt, return (logits, cache ready for decode at pos=T)."""
    b, t = tokens.shape
    cache = init_cache(cfg, b, max_len or t)
    logits, cache, _ = apply(cfg, params, tokens, mode="prefill",
                             cache=cache, scan_unroll=scan_unroll)
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, token, pos,
                scan_unroll: bool = False):
    """token: (B, 1) -> (logits (B,1,V), new cache)."""
    logits, cache, _ = apply(cfg, params, token, mode="decode", cache=cache,
                             pos=pos, scan_unroll=scan_unroll)
    return logits, cache
