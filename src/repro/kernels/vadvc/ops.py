"""Jitted public entry points for vadvc (planner-aware dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune, tiling
from repro.kernels.vadvc import ref as _ref
from repro.kernels.vadvc.vadvc import vadvc_pallas


def plan_tile(grid_shape, dtype):
    """Auto-tuned (tj, ti) horizontal window (paper's 64x2 fp32 analogue).

    Snapping goes through `tiling.snap_to_divisor` (largest divisor below
    the tuned extent) — the same rule as every other kernel package; the
    old private power-of-two halving drifted from the unified
    `resolve_tile` path on non-power-of-two extents."""
    tuned = autotune.tune_named("vadvc", grid_shape, dtype)
    _, tj, ti = tuned.plan.tile
    nz, ny, nx = grid_shape
    return (tiling.snap_to_divisor(tj, ny, lo=1),
            tiling.snap_to_divisor(ti, nx, lo=1))


def resolve_tile(grid_shape, dtype) -> tiling.TilePlan:
    """Planner entry (`weather/program.py::compile`): the auto-tuned,
    snapped (tj, ti) window as a full `TilePlan` over the vadvc tile space
    (z stays whole — the Thomas solve is sequential in z)."""
    tj, ti = plan_tile(grid_shape, dtype)
    return tiling.TilePlan(op=autotune.get_op("vadvc"),
                           grid_shape=tuple(int(g) for g in grid_shape),
                           tile=(int(grid_shape[0]), tj, ti),
                           dtype=str(jnp.dtype(dtype)))


@functools.partial(jax.jit, static_argnames=("use_pallas", "tj", "ti",
                                             "interpret"))
def vadvc(u_stage, wcon, u_pos, utens, utens_stage,
          use_pallas: bool = False, tj: int = 0, ti: int = 0,
          interpret: bool = True):
    if use_pallas:
        if not (tj and ti):
            tj, ti = plan_tile(u_stage.shape, u_stage.dtype)
        return vadvc_pallas(u_stage, wcon, u_pos, utens, utens_stage,
                            tj=tj, ti=ti, interpret=interpret)
    return _ref.vadvc(u_stage, wcon, u_pos, utens, utens_stage)
