"""Distributed dycore: spatial domain decomposition + halo exchange.

This is NERO's scale-out story made real (paper §5: "HBM provides an
attractive solution for scale-out computation" with one memory channel per
PE): every chip owns an (ny/Py, nx/Px) slab of the horizontal domain in its
own HBM; the compound stencils run chip-locally out of VMEM; the only
communication is a 2-deep circular halo exchange (`jax.lax.ppermute` over the
mesh axes) before the horizontal stencil, plus a 1-column exchange for the
x-staggered `wcon` before the vertical solve.  Vertical columns are never
split (vadvc's z dependency), matching the paper's PE design.

Ensemble members ride the "pod" axis of the multi-pod mesh: weather centers
run ~50-member ensembles, which is exactly a data-parallel outer axis.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import PROGNOSTIC, WeatherState
from repro.weather.dycore import HALO


def _exchange(f: jnp.ndarray, axis_name: str, n: int, halo: int,
              dim: int) -> jnp.ndarray:
    """Circular halo exchange along `dim` over mesh axis `axis_name`.

    Returns f extended by `halo` on both sides of `dim`.  With n == 1 this
    degenerates to periodic wrap-padding (no communication)."""
    def take(a, sl):
        idx = [slice(None)] * a.ndim
        idx[dim] = sl
        return a[tuple(idx)]

    lo = take(f, slice(0, halo))          # my first rows -> neighbor below
    hi = take(f, slice(-halo, None))      # my last rows  -> neighbor above
    if n == 1:
        top, bot = hi, lo
    else:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        top = jax.lax.ppermute(hi, axis_name, perm=fwd)   # from rank-1
        bot = jax.lax.ppermute(lo, axis_name, perm=bwd)   # from rank+1
    return jnp.concatenate([top, f, bot], axis=dim)


def _local_hdiff(f: jnp.ndarray, coeff: float, ax_y: str, ax_x: str,
                 ny_shards: int, nx_shards: int) -> jnp.ndarray:
    """f: (E, nz, ly, lx) local slab -> diffused slab."""
    e, nz, ly, lx = f.shape
    g = _exchange(f, ax_y, ny_shards, HALO, dim=2)
    g = _exchange(g, ax_x, nx_shards, HALO, dim=3)
    out = hdiff_ref.hdiff(g.reshape(e * nz, ly + 2 * HALO, lx + 2 * HALO),
                          coeff=coeff)
    out = out.reshape(e, nz, ly + 2 * HALO, lx + 2 * HALO)
    return out[:, :, HALO:HALO + ly, HALO:HALO + lx]


def _local_vadvc(u_stage, wcon, u_pos, utens, utens_stage, ax_x, nx_shards):
    """All (E, nz, ly, lx); staggered wcon column fetched from x-neighbor."""
    e, nz, ly, lx = u_stage.shape
    if nx_shards == 1:
        right = wcon[..., :1]
    else:
        bwd = [(i, (i - 1) % nx_shards) for i in range(nx_shards)]
        right = jax.lax.ppermute(wcon[..., :1], ax_x, perm=bwd)
    wcon_s = jnp.concatenate([wcon, right], axis=-1)
    # vmap over ensemble; fields already (nz, ly, lx) per member.
    out = jax.vmap(vadvc_ref.vadvc)(u_stage, wcon_s, u_pos, utens,
                                    utens_stage)
    return out


def make_distributed_step(mesh: Mesh, *, coeff: float = 0.025,
                          dt: float = 0.1, ax_e: str | None = "pod",
                          ax_y: str = "data", ax_x: str = "model"):
    """Build the jitted distributed dycore step for `mesh`.

    Sharding: ensemble over `ax_e` (if present in the mesh), y over `ax_y`,
    x over `ax_x`; z always chip-local."""
    have_e = ax_e is not None and ax_e in mesh.axis_names
    e_spec = ax_e if have_e else None
    spec = P(e_spec, None, ax_y, ax_x)
    ny_shards = mesh.shape[ax_y]
    nx_shards = mesh.shape[ax_x]

    def local_step(fields, wcon, tens, stage_tens):
        new_fields, new_stage = {}, {}
        for name in PROGNOSTIC:
            f = fields[name]
            stage = _local_vadvc(f, wcon, f, tens[name], stage_tens[name],
                                 ax_x, nx_shards)
            f = f + dt * stage
            f = _local_hdiff(f, coeff, ax_y, ax_x, ny_shards, nx_shards)
            new_fields[name] = f
            new_stage[name] = stage
        return new_fields, new_stage

    sharded = shard_map(
        local_step, mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, spec),
        check_rep=False)

    @jax.jit
    def step(state: WeatherState) -> WeatherState:
        new_fields, new_stage = sharded(state.fields, state.wcon, state.tens,
                                        state.stage_tens)
        return WeatherState(fields=new_fields, wcon=state.wcon,
                            tens=state.tens, stage_tens=new_stage)

    return step, spec


def shard_state(state: WeatherState, mesh: Mesh, spec: P) -> WeatherState:
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda a: jax.device_put(a, sharding), state)
