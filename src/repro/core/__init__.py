"""NERO core: near-memory tiling engine, autotuner, perf model, roofline."""
