"""COSMO-like dynamical core built from the paper's compound kernels.

One `dycore_step` applies the three computational patterns the paper names
(§1): horizontal stencils (hdiff), tridiagonal solves in the vertical
(vadvc), and point-wise computation (the explicit update).  It is a
*representative* dycore, faithful to the kernels and their composition, not a
full COSMO port.

Three execution paths (see docs/architecture.md for the dataflow diagram):

  * `fused=True, whole_state=True` (default): ALL prognostic fields run as
    ONE Pallas compound kernel per step (kernels/dycore_fused whole-state
    variant) — the per-stage intermediates never leave VMEM *and* the
    shared staggered-velocity slab is streamed from HBM once per step
    instead of once per field.  One kernel launch per timestep.
  * `fused=True, whole_state=False`: the per-field fused pipeline — one
    `pallas_call` per prognostic field.  Kept as the launch-granularity
    oracle the whole-state path is tested/benchmarked against.
  * `fused=False`: the original unfused composition — wrap-pad, per-kernel
    jnp oracles, every intermediate materialized in HBM.  It is kept both as
    the fallback for backends without Pallas support and as the equivalence
    oracle the fused paths are tested against.

The domain is doubly periodic in (y, x) — the standard dycore test setup —
so the distributed version (weather/domain.py) only needs circular halo
exchanges.  Periodic variants of the kernels are expressed with jnp.roll on
top of the validated interior kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dycore_fused import ops as fused_ops
from repro.kernels.dycore_fused.ops import _auto_interpret
from repro.kernels.dycore_fused.ref import pad_periodic
from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import PROGNOSTIC, WeatherState

HALO = 2   # hdiff needs 2; vadvc needs 1 (staggered wcon)


def hdiff_periodic(src: jnp.ndarray, coeff: float) -> jnp.ndarray:
    """Periodic compound horizontal diffusion of a (..., nz, ny, nx) field."""
    ny, nx = src.shape[-2:]
    flat = src.reshape((-1,) + src.shape[-3:])

    def one(f):
        padded = pad_periodic(f, HALO)
        out = hdiff_ref.hdiff(padded, coeff=coeff)
        return out[:, HALO:HALO + ny, HALO:HALO + nx]

    return jax.vmap(one)(flat).reshape(src.shape)


def vadvc_field(u_stage, wcon, u_pos, utens, utens_stage):
    """vadvc over a (..., nz, ny, nx) field.  `wcon` is (..., nz, ny, nx)
    and is wrap-padded to the staggered (nx+1) extent (periodic domain)."""
    shape = u_stage.shape
    wcon_s = jnp.concatenate([wcon, wcon[..., :1]], axis=-1)
    flat = lambda a: a.reshape((-1,) + a.shape[-3:])
    out = jax.vmap(vadvc_ref.vadvc)(flat(u_stage), flat(wcon_s), flat(u_pos),
                                    flat(utens), flat(utens_stage))
    return out.reshape(shape)


def stack_state(d: dict) -> jnp.ndarray:
    """Stack the per-field dict onto a new axis -4: (..., nf, nz, ny, nx)."""
    return jnp.stack([d[name] for name in PROGNOSTIC], axis=-4)


def unstack_state(a: jnp.ndarray) -> dict:
    """Inverse of `stack_state`."""
    return {name: jnp.take(a, i, axis=-4)
            for i, name in enumerate(PROGNOSTIC)}


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "fused",
                                             "whole_state", "interpret"))
def dycore_step(state: WeatherState, coeff: float = 0.025,
                dt: float = 0.1, fused: bool = True,
                whole_state: bool = True,
                interpret: bool | None = None) -> WeatherState:
    """One large-timestep: vertical-implicit advection per field, explicit
    point-wise update, horizontal diffusion smoothing.

    `fused=True, whole_state=True` (default) runs every prognostic field in
    a single Pallas launch with the staggered-velocity slab shared across
    fields; `whole_state=False` keeps the per-field fused pipeline;
    `fused=False` is the unfused oracle composition (identical math, every
    intermediate round-tripping HBM)."""
    new_fields, new_stage = {}, {}
    if fused and whole_state:
        f_new, stage = fused_ops.fused_step_whole_state(
            stack_state(state.fields), state.wcon, stack_state(state.tens),
            stack_state(state.stage_tens), coeff=coeff, dt=dt,
            interpret=interpret)
        new_fields = unstack_state(f_new)
        new_stage = unstack_state(stage)
    elif fused:
        if interpret is None:
            interpret = _auto_interpret()
        for name in PROGNOSTIC:
            f_new, stage = fused_ops.fused_step(
                state.fields[name], state.wcon, state.tens[name],
                state.stage_tens[name], coeff=coeff, dt=dt,
                interpret=interpret)
            new_fields[name] = f_new
            new_stage[name] = stage
    else:
        for name in PROGNOSTIC:
            f = state.fields[name]
            # 1) tridiagonal vertical solve -> updated stage tendency
            stage = vadvc_field(u_stage=f, wcon=state.wcon, u_pos=f,
                                utens=state.tens[name],
                                utens_stage=state.stage_tens[name])
            # 2) point-wise explicit update
            f = f + dt * stage
            # 3) compound horizontal diffusion
            f = hdiff_periodic(f, coeff)
            new_fields[name] = f
            new_stage[name] = stage
    return WeatherState(fields=new_fields, wcon=state.wcon,
                        tens=state.tens, stage_tens=new_stage)


def run(state: WeatherState, steps: int, coeff: float = 0.025,
        dt: float = 0.1, fused: bool = True,
        whole_state: bool = True, k_steps: int = 1,
        interpret: bool | None = None) -> WeatherState:
    """Advance `steps` timesteps.  With `k_steps > 1` (requires the fused
    whole-state path and `steps % k_steps == 0`) the trajectory runs as
    `steps / k_steps` k-step rounds, each ONE Pallas launch whose kernel
    iterates the k local steps with the prognostic state held in VMEM
    (`kernels/dycore_fused/ops.py::fused_step_kstep`) — the single-chip
    face of the distributed communication-avoiding mode."""
    if k_steps < 1:
        raise ValueError(f"k_steps={k_steps} must be >= 1")
    if k_steps > 1 and not (fused and whole_state):
        raise ValueError("k_steps > 1 requires the fused whole-state path")
    if steps % k_steps:
        raise ValueError(f"steps={steps} must be a multiple of "
                         f"k_steps={k_steps}")
    if k_steps > 1:
        def body(s, _):
            f_new, stage = fused_ops.fused_step_kstep(
                stack_state(s.fields), s.wcon, stack_state(s.tens),
                stack_state(s.stage_tens), k_steps=k_steps, coeff=coeff,
                dt=dt, interpret=interpret)
            return WeatherState(fields=unstack_state(f_new), wcon=s.wcon,
                                tens=s.tens,
                                stage_tens=unstack_state(stage)), ()

        final, _ = jax.lax.scan(body, state, (), length=steps // k_steps)
        return final

    def body(s, _):
        return dycore_step(s, coeff=coeff, dt=dt, fused=fused,
                           whole_state=whole_state, interpret=interpret), ()

    final, _ = jax.lax.scan(body, state, (), length=steps)
    return final
