"""Jitted public entry points for the fused dycore step (planner-aware).

Two granularities:

* `fused_step(...)` — one prognostic field per call: builds the pre-combined
  staggered vertical velocity, picks the auto-tuned y-window (NERO's
  OpenTuner stage via core/autotune.py), and dispatches to the Pallas
  compound kernel — or to the unfused oracle composition when
  `use_pallas=False` (the differentiable fallback path).
* `fused_step_whole_state(...)` — ALL prognostic fields in ONE `pallas_call`:
  fields are stacked on a leading `nf` axis, the shared staggered-velocity
  slab is DMA'd once per (ensemble, y-window) instead of once per field, and
  the launch cost is amortized nf×.  The default (`variant="whole_state"`)
  hot path of compiled dycore plans (`weather/program.py::compile`).
* `fused_step_kstep(...)` — the whole k-step round in ONE `pallas_call`: the
  kernel body runs the k local steps internally, prognostic state between
  steps lives in VMEM scratch, and the shared `w` slab is double-buffer
  prefetched across y-windows (`kernels/dycore_fused/fused.py::
  fused_dycore_kstep_pallas`).  The hot path of every `variant="kstep"`
  dycore plan (`weather/program.py::compile`), single-chip and
  distributed (the communication-avoiding mode).

Both default `interpret=None`, resolved via `_auto_interpret()`: native
Pallas on TPU, interpreter everywhere else.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune, hierarchy as hw, tiling
from repro.kernels.dycore_fused import ref as _ref
from repro.kernels.dycore_fused.fused import (HALO,
                                              fused_dycore_kstep_pallas,
                                              fused_dycore_pallas,
                                              fused_dycore_whole_state_pallas)

DEFAULT_COEFF = _ref.DEFAULT_COEFF
DEFAULT_DT = _ref.DEFAULT_DT


def _auto_interpret() -> bool:
    """Pallas runs natively on TPU, in interpreter mode everywhere else."""
    return jax.default_backend() != "tpu"


def snap_ty(ty: int, ny: int) -> int:
    """Largest legal y-window <= `ty`: a divisor of ny, >= 2 (falling back to
    a single whole-y window when ny has no divisor in [2, ty])."""
    return tiling.snap_to_divisor(ty, ny, lo=2)


def plan_tile(grid_shape, dtype) -> int:
    """Auto-tuned y-window for the fused kernel (paper Fig. 6 stage)."""
    tuned = autotune.tune_named("dycore_fused", grid_shape, dtype)
    return snap_ty(tuned.plan.tile[1], grid_shape[1])


def snap_ty_kstep(ty: int, ny: int, k_steps: int) -> int:
    """Legal k-step y-window: a divisor of `ny` that is at least
    `k_steps * HALO` (each local step consumes a HALO-deep ring of window
    validity).  Prefers the largest legal divisor <= `ty`; falls back to the
    smallest legal divisor (possibly ny itself) when `ty` is too small."""
    lo = max(2, k_steps * HALO)
    if ny < lo:
        raise ValueError(
            f"ny={ny} < k_steps*HALO={lo}: no window can hold the k-step "
            f"validity front; use a bigger grid or a smaller k_steps")
    divisors = [d for d in range(lo, ny + 1) if ny % d == 0]
    at_most = [d for d in divisors if d <= ty]
    return at_most[-1] if at_most else divisors[0]


def plan_tile_kstep(grid_shape, dtype, n_fields: int, k_steps: int,
                    hier=None) -> int:
    """Auto-tuned y-window for the k-step kernel.

    The k-step tile space (`tiling.dycore_kstep_spec`) is far tighter than
    the whole-state one: every grid cell stages a 3-window working slab, all
    8 pipeline temporaries span it, and the double-buffered `w` prefetch
    adds two more padded buffers.  After the Pareto pick the window is
    snapped to a divisor of ny that clears the `ty >= k_steps*HALO`
    validity-front bound, and the snapped plan is re-checked against the
    VMEM budget — plans that do not fit the double buffer are rejected
    loudly instead of silently spilling."""
    hier = hier or hw.tpu_v5e()
    spec = tiling.dycore_kstep_spec(n_fields, k_steps)
    tuned = autotune.tune(spec, grid_shape, dtype, hier=hier)
    ty = snap_ty_kstep(tuned.plan.tile[1], grid_shape[1], k_steps)
    plan = tiling.TilePlan(op=spec, grid_shape=tuple(grid_shape),
                           tile=(grid_shape[0], ty, grid_shape[2]),
                           dtype=str(jnp.dtype(dtype)))
    if not plan.fits(hier):
        raise ValueError(
            f"k-step tile plan ty={ty} for grid={tuple(grid_shape)} "
            f"k_steps={k_steps} needs {plan.vmem_bytes / 2**20:.1f} MiB of "
            f"VMEM (3-window scratch + double-buffered w prefetch) but only "
            f"{hier.vmem.capacity_bytes / 2**20:.1f} MiB fit; use a smaller "
            f"k_steps or grid")
    return ty


def resolve_tile(variant: str, grid_shape, dtype, n_fields: int,
                 k_steps: int = 1, hier=None):
    """ONE tile resolver for every fused-dycore execution variant — the
    planner entry `weather/program.py::compile_dycore` calls instead of
    picking among the three `plan_tile*` paths itself.  Returns the
    auto-tuned, snapped y-window, or None for the unfused oracle (which
    has no Pallas tile to plan)."""
    if variant == "unfused":
        return None
    if variant == "per_field":
        return plan_tile(grid_shape, dtype)
    if variant == "whole_state":
        return plan_tile_whole_state(grid_shape, dtype, n_fields)
    if variant == "kstep":
        return plan_tile_kstep(grid_shape, dtype, n_fields, k_steps,
                               hier=hier)
    raise ValueError(f"unknown dycore variant {variant!r}")


def plan_tile_whole_state(grid_shape, dtype, n_fields: int) -> int:
    """Auto-tuned y-window for the whole-state kernel.

    The whole-state tile space differs from the per-field one: the shared
    `w` slab amortizes to 1/n_fields of input *traffic* but stays fully
    resident in VMEM alongside the per-field windows, so the legal tile set
    (and the Pareto pick) shifts with the field count.  The default
    (4-field) space lives in the autotune registry as
    "dycore_whole_state"; here the spec for the *actual* `n_fields` is
    built and tuned directly, leaving the registry untouched.
    """
    spec = tiling.dycore_whole_state_spec(n_fields)
    tuned = autotune.tune(spec, grid_shape, dtype)
    return snap_ty(tuned.plan.tile[1], grid_shape[1])


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "use_pallas",
                                             "ty", "interpret"))
def fused_step(f: jnp.ndarray, wcon: jnp.ndarray, utens: jnp.ndarray,
               utens_stage: jnp.ndarray, coeff: float = DEFAULT_COEFF,
               dt: float = DEFAULT_DT, use_pallas: bool = True, ty: int = 0,
               interpret: bool | None = None):
    """One fused dycore field step on a doubly-periodic (..., nz, ny, nx)
    domain.  `wcon` is the unstaggered vertical velocity; the kernel's
    staggered neighbor is the periodic next x-column.  Returns
    (f_new, stage)."""
    if not use_pallas:
        return _ref.fused_step_ref_batched(f, wcon, utens, utens_stage,
                                           coeff=coeff, dt=dt)
    if interpret is None:
        interpret = _auto_interpret()
    ny = f.shape[-2]
    ty = snap_ty(ty, ny) if ty else plan_tile(f.shape[-3:], f.dtype)
    w = wcon + jnp.roll(wcon, -1, axis=-1)   # wcon_i + wcon_{i+1}, periodic
    return fused_dycore_pallas(f, w, utens, utens_stage, coeff=coeff, dt=dt,
                               ty=ty, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "use_pallas",
                                             "ty", "interpret"))
def fused_step_whole_state(fs: jnp.ndarray, wcon: jnp.ndarray,
                           utens: jnp.ndarray, utens_stage: jnp.ndarray,
                           coeff: float = DEFAULT_COEFF,
                           dt: float = DEFAULT_DT, use_pallas: bool = True,
                           ty: int = 0, interpret: bool | None = None):
    """Whole-state fused dycore step: `fs`/`utens`/`utens_stage` are
    field-stacked (..., nf, nz, ny, nx); `wcon` is the shared unstaggered
    vertical velocity (..., nz, ny, nx).  One `pallas_call` covers every
    field; see `fused_dycore_whole_state_pallas`.  Returns (f_new, stage)
    shaped like `fs`."""
    if not use_pallas:
        wb = jnp.broadcast_to(jnp.expand_dims(wcon, -4), fs.shape)
        return _ref.fused_step_ref_batched(fs, wb, utens, utens_stage,
                                           coeff=coeff, dt=dt)
    if interpret is None:
        interpret = _auto_interpret()
    nf, _, ny, _ = fs.shape[-4:]
    ty = (snap_ty(ty, ny) if ty
          else plan_tile_whole_state(fs.shape[-3:], fs.dtype, nf))
    w = wcon + jnp.roll(wcon, -1, axis=-1)   # wcon_i + wcon_{i+1}, periodic
    return fused_dycore_whole_state_pallas(fs, w, utens, utens_stage,
                                           coeff=coeff, dt=dt, ty=ty,
                                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k_steps", "coeff", "dt", "ty",
                                             "interpret", "prefetch_w"))
def fused_step_kstep(fs: jnp.ndarray, wcon: jnp.ndarray,
                     utens: jnp.ndarray, utens_stage: jnp.ndarray,
                     k_steps: int = 2, coeff: float = DEFAULT_COEFF,
                     dt: float = DEFAULT_DT, ty: int = 0,
                     interpret: bool | None = None,
                     prefetch_w: bool | None = None):
    """Advance the whole stacked state `k_steps` timesteps in ONE
    `pallas_call` (`fused_dycore_kstep_pallas`): the k-step time loop runs
    inside the kernel, state between local steps stays in VMEM, and the
    shared staggered-velocity slab is double-buffer-prefetched across
    y-windows (`prefetch_w`, default on outside interpret mode).

    Shapes as `fused_step_whole_state`; doubly periodic domain.  Returns
    `(f_new, stage)` after `k_steps` steps."""
    if interpret is None:
        interpret = _auto_interpret()
    nf, _, ny, _ = fs.shape[-4:]
    ty = (snap_ty_kstep(ty, ny, k_steps) if ty
          else plan_tile_kstep(fs.shape[-3:], fs.dtype, nf, k_steps))
    w = wcon + jnp.roll(wcon, -1, axis=-1)   # wcon_i + wcon_{i+1}, periodic
    return fused_dycore_kstep_pallas(fs, w, utens, utens_stage,
                                     k_steps=k_steps, coeff=coeff, dt=dt,
                                     ty=ty, interpret=interpret,
                                     prefetch_w=prefetch_w)
