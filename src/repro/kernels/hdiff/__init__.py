"""NERO kernel package: hdiff."""
