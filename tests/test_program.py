"""Declarative dycore programs: `compile` planner coverage (dycore op).

This module exercises the plan API on the dycore op (per-op hdiff/vadvc
coverage lives in tests/test_stencil_program.py).  The legacy flag-soup
shims were RETIRED this PR; `test_legacy_shims_removed` pins that down,
and CI still runs the module under `python -W error::DeprecationWarning`
to prove no production path warns."""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.weather import fields
from repro.weather.program import (DycoreProgram, ExchangeSchedule,
                                   ExecutionPlan, compile_dycore)


def _max_err(a, b, name):
    return np.abs(np.asarray(a.fields[name]) - np.asarray(b.fields[name]))


def test_program_validation():
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8))                 # not a triple
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), variant="bogus")
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), boundary="dirichlet")
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), k_steps=0)
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), k_steps=2, variant="per_field")
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), k_steps=1, variant="kstep")
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), halo=3)
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), fields=())
    with pytest.raises(ValueError):
        DycoreProgram(grid_shape=(4, 8, 8), op="not-a-registered-op")
    with pytest.raises(TypeError):
        compile_dycore({"grid_shape": (4, 8, 8)})
    # programs are immutable specs
    with pytest.raises(dataclasses.FrozenInstanceError):
        DycoreProgram(grid_shape=(4, 8, 8)).ensemble = 2


def test_plan_selection_deterministic():
    """The planner is a pure function of (program, mesh): compiling the
    same spec twice yields identical plans/reports across (grid, dtype,
    k) combos — no hidden state, no ordering effects."""
    combos = (((4, 8, 8), "float32", "auto"),
              ((4, 16, 16), "float32", 2),
              ((8, 32, 16), "bfloat16", 1),
              ((4, 12, 16), "float32", 4))
    for grid, dtype, k in combos:
        prog = DycoreProgram(grid_shape=grid, dtype=dtype, k_steps=k)
        p1, p2 = compile_dycore(prog), compile_dycore(prog)
        assert p1.report() == p2.report(), (grid, dtype, k)
        assert p1.variant == p2.variant and p1.tile_ty == p2.tile_ty
        want = "kstep" if isinstance(k, int) and k > 1 else "whole_state"
        assert p1.variant == want          # single-chip auto resolves k=1
        assert isinstance(p1, ExecutionPlan)


def test_plan_resolution_and_structure():
    """Variant/k/tile resolution: auto -> whole-state on a single chip,
    explicit kstep keeps its k, and the structural counts are the
    single-chip ones (no collectives; 1 launch per round except the
    per-field/unfused oracles)."""
    grid = (4, 16, 16)
    auto = compile_dycore(DycoreProgram(grid_shape=grid))
    assert (auto.variant, auto.k_steps) == ("whole_state", 1)
    assert auto.collectives_per_round == 0
    assert auto.pallas_calls_per_round == 1
    assert auto.exchange is None and auto.state_spec is None

    k = compile_dycore(DycoreProgram(grid_shape=grid, variant="kstep",
                                     k_steps=2))
    assert (k.variant, k.k_steps) == ("kstep", 2)
    assert k.pallas_calls_per_round == 1
    assert k.tile_ty >= 2 * k.program.halo     # the validity-front bound

    pf = compile_dycore(DycoreProgram(grid_shape=grid, variant="per_field",
                                      k_steps=1))
    assert pf.pallas_calls_per_round == len(fields.PROGNOSTIC)
    un = compile_dycore(DycoreProgram(grid_shape=grid, variant="unfused"))
    assert un.pallas_calls_per_round == 0 and un.tile_ty is None


def test_plan_report_is_machine_readable():
    """report() is plain JSON (benchmarks embed it verbatim in
    BENCH_dycore.json) and carries the full strategy: variant, tile,
    k_steps, exchange, structural counts, modeled traffic."""
    plan = compile_dycore(DycoreProgram(grid_shape=(4, 16, 16),
                                        variant="kstep", k_steps=2))
    rep = plan.report()
    rep2 = json.loads(json.dumps(rep))
    assert rep2 == rep                          # round-trips losslessly
    assert rep["variant"] == "kstep" and rep["k_steps"] == 2
    assert rep["tile"]["op"] == "dycore_kstep"
    assert rep["tile"]["ty"] == rep["tile"]["tile"][1]
    assert rep["tile"]["vmem_bytes"] > 0
    assert rep["pallas_calls_per_round"] == 1
    assert rep["traffic"]["fused_kstep"]["total"] > 0
    assert rep["exchange"] is None              # single chip
    assert rep["program"]["fields"] == list(fields.PROGNOSTIC)


def test_plan_step_checks_state():
    st = fields.initial_state(jax.random.PRNGKey(0), (4, 8, 8))
    plan = compile_dycore(DycoreProgram(grid_shape=(4, 16, 16)))
    with pytest.raises(ValueError, match="grid"):
        plan.step(st)
    bf = compile_dycore(DycoreProgram(grid_shape=(4, 8, 8),
                                      dtype="bfloat16"))
    with pytest.raises(ValueError, match="precision"):
        bf.step(st)
    with pytest.raises(ValueError):
        compile_dycore(DycoreProgram(grid_shape=(4, 8, 8))).run(st, -1)


def test_plan_run_ragged_tail_matches_sequential():
    """plan.run(steps) with steps % k_steps != 0 executes a shorter TAIL
    round (k' = steps mod k) instead of raising — equivalent to the
    sequential whole-state trajectory within the limiter-fragile
    tolerance (ISSUE 4 satellite)."""
    grid = (4, 12, 16)
    st = fields.initial_state(jax.random.PRNGKey(3), grid, ensemble=2)
    seq = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2,
                                       k_steps=1, variant="whole_state"))
    want = seq.run(st, 5)
    for k in (2, 3):
        kplan = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2,
                                             variant="kstep", k_steps=k))
        got = kplan.run(st, 5)                  # full rounds + ragged tail
        for name in fields.PROGNOSTIC:
            err = _max_err(got, want, name)
            bad = int((err > 1e-5).sum())
            assert bad <= 4 and err.max() < 0.05, (k, name, bad, err.max())
    # steps == 0 is a no-op, steps < k is ONE tail round
    same = kplan.run(st, 0)
    assert np.array_equal(np.asarray(same.fields["t"]),
                          np.asarray(st.fields["t"]))
    one = kplan.run(st, 1)
    err = _max_err(one, seq.run(st, 1), name="t")
    assert err.max() < 1e-6


def test_legacy_shims_removed():
    """The flag-soup era is over (retired ROADMAP item): the deprecated
    `dycore_step`/`run`/`make_distributed_step` shims are gone — plans are
    the only execution surface — while the first-class helpers the plan
    lowerings build on remain."""
    from repro.weather import domain, dycore
    for mod, name in ((dycore, "dycore_step"), (dycore, "run"),
                      (domain, "make_distributed_step")):
        assert not hasattr(mod, name), f"{name} should be retired"
    for mod, name in ((dycore, "hdiff_periodic"), (dycore, "vadvc_field"),
                      (dycore, "stack_state"), (domain, "_exchange_packed"),
                      (domain, "shard_state")):
        assert hasattr(mod, name), f"{name} should remain first-class"


# ---------------------------------------------------------------------------
# Distributed plans: report() must equal the traced structure
# ---------------------------------------------------------------------------

_DIST_PLAN_SNIPPET = r"""
import jax, numpy as np
from repro.core import trace_stats
from repro.weather import domain, fields
from repro.weather.program import DycoreProgram, compile_dycore
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
grid = (4, 16, 16)
st = fields.initial_state(jax.random.PRNGKey(0), grid, ensemble=2)

# report() == traced structure, for EVERY variant: the plan's modeled
# pallas_calls_per_round / collectives_per_round are the program text's
# actual primitive counts.
plans = {}
for variant, k in (("kstep", 2), ("whole_state", 1), ("per_field", 1),
                   ("unfused", 1)):
    plan = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2,
                                        variant=variant, k_steps=k),
                          mesh=mesh)
    rep = plan.report()
    j = jax.make_jaxpr(plan.step)(st)
    trace_stats.assert_plan_structure(j, rep)
    plans[variant] = plan

# the distributed k-step plan's contract (ISSUE 4 acceptance criterion)
assert plans["kstep"].report()["collectives_per_round"] == 4
assert plans["kstep"].report()["pallas_calls_per_round"] == 1
assert plans["whole_state"].report()["collectives_per_round"] == 4

# the ragged exchange schedule: wcon's +1 staggering column is RIGHT-only
sched = plans["kstep"].report()["exchange"]
assert sched["mode"] == "packed"
assert sched["wcon_depth_x"] == [sched["depth_x"], sched["depth_x"] + 1]

# distributed ragged tail: 3 steps on a k=2 plan == 3 sequential rounds
sst = domain.shard_state(st, mesh, plans["kstep"].state_spec)
got = plans["kstep"].run(sst, 3)
want = sst
for _ in range(3):
    want = plans["whole_state"].step(want)
for name in fields.PROGNOSTIC:
    err = np.abs(np.asarray(got.fields[name]) - np.asarray(want.fields[name]))
    bad = int((err > 1e-5).sum())
    assert bad <= 2 and err.max() < 0.05, (name, bad, err.max())

# bf16 wire policy resolves into the schedule (and still 4 collectives)
bplan = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2,
                                     variant="kstep", k_steps=2,
                                     exchange_dtype="bfloat16"), mesh=mesh)
assert bplan.report()["exchange"]["wire_dtype"] == "bfloat16"
trace_stats.assert_plan_structure(jax.make_jaxpr(bplan.step)(st),
                                  bplan.report())

# k_steps="auto": resolved at compile time, deterministically
a1 = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2), mesh=mesh)
a2 = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2), mesh=mesh)
assert a1.k_steps == a2.k_steps >= 1 and a1.variant == a2.variant

# a variant pinned to one step per round + the default k_steps="auto"
# must resolve k=1 on a mesh, not crash on the auto-resolved deep k
ws = compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2,
                                  variant="whole_state"), mesh=mesh)
assert (ws.variant, ws.k_steps) == ("whole_state", 1)

# too-deep halo refuses loudly at compile time
try:
    compile_dycore(DycoreProgram(grid_shape=(4, 8, 8), variant="kstep",
                                 k_steps=4), mesh=mesh)
except ValueError as e:
    assert "halo" in str(e), e
else:
    raise AssertionError("k_steps=4 on a 4-row slab should refuse")
print("PLAN_DIST_OK")
"""


def _run_forced_device_snippet(snippet: str, marker: str):
    """Run `snippet` in a subprocess with 4 forced host CPU devices."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert marker in r.stdout, r.stderr[-2000:]


def test_distributed_plan_report_matches_trace():
    """Forced-4-device subprocess: for every variant the plan's report()
    equals the traced launch/collective counts, the distributed k-step
    plan reports collectives_per_round == 4, the ragged tail round is
    equivalent to sequential stepping, and compile-time validation
    refuses a halo deeper than the local slab."""
    _run_forced_device_snippet(_DIST_PLAN_SNIPPET, "PLAN_DIST_OK")


def test_exchange_schedule_describe():
    """The schedule is rides-first (per-operand (lo, hi) depths straight
    from the registry) but keeps the legacy depth_y/depth_x/wcon_depth_x
    summary keys the CI plan-block check and cross-PR diffs read."""
    s = ExchangeSchedule(mode="packed", shards=(2, 2),
                         rides=(("fields", (4, 4), (4, 4)),
                                ("wcon", (4, 4), (4, 5))),
                         wire_dtype="bfloat16")
    assert (s.depth_y, s.depth_x, s.wcon_depth_x) == (4, 4, (4, 5))
    d = s.describe()
    assert d["mode"] == "packed" and d["shards"] == [2, 2]
    assert d["rides"]["wcon"] == {"depth_y": [4, 4], "depth_x": [4, 5]}
    assert d["wcon_depth_x"] == [4, 5] and d["depth_y"] == 4
    assert d["wire_dtype"] == "bfloat16"
    # an op with no wcon ride (hdiff) simply omits the wcon summary key
    h = ExchangeSchedule(mode="packed", shards=(2, 2),
                         rides=(("fields", (2, 2), (2, 2)),),
                         wire_dtype=None)
    assert "wcon_depth_x" not in h.describe()
    assert h.wcon_depth_x is None


def test_plan_cache_key_and_json_roundtrip():
    """The frozen program IS the plan-cache key: ensemble rebinding is the
    only transform, the spec survives a JSON round-trip bit-for-bit, and
    rebound keys hash/compare like the directly-constructed spec (the
    serving engine keys its plan cache on exactly this)."""
    import jax.numpy as jnp

    from repro.weather.program import StencilProgram, plan_cache_key
    p = StencilProgram(grid_shape=(4, 8, 8), op="hdiff",
                       dtype=jnp.bfloat16)   # non-canonical spelling
    assert plan_cache_key(p) is p                   # no rebind, no copy
    assert plan_cache_key(p, ensemble=1) is p       # ensemble already 1
    k4 = plan_cache_key(p, ensemble=4)
    assert k4.ensemble == 4 and k4.dtype == "bfloat16"    # normalized
    assert k4 == StencilProgram(grid_shape=(4, 8, 8), op="hdiff",
                                dtype="bfloat16", ensemble=4)
    assert {k4: "plan"}[plan_cache_key(p, ensemble=4)] == "plan"
    # JSON round-trip: to_json is plain-serializable, from_json rebuilds
    # an equal (hence same-cache-slot) spec
    d = json.loads(json.dumps(k4.to_json()))
    back = StencilProgram.from_json(d)
    assert back == k4 and hash(back) == hash(k4)


def test_ensemble_slot_helpers():
    """Slot view/assign/select are the engine's admission/retire/rollback
    primitives: a view keeps the leading axis, assign scatters member
    states into batch slots, select mixes per-slot old/new."""
    from repro.weather.program import (ensemble_slot_assign,
                                       ensemble_slot_select,
                                       ensemble_slot_view)
    grid = (3, 8, 8)
    batch = fields.initial_state(jax.random.PRNGKey(0), grid, ensemble=3)
    one = fields.initial_state(jax.random.PRNGKey(1), grid, ensemble=1)
    v = ensemble_slot_view(batch, 1)
    for name in fields.PROGNOSTIC:
        assert v.fields[name].shape[0] == 1
        assert np.array_equal(np.asarray(v.fields[name]),
                              np.asarray(batch.fields[name][1:2]))
    put = ensemble_slot_assign(batch, np.asarray([2]), one)
    for name in fields.PROGNOSTIC:
        assert np.array_equal(np.asarray(put.fields[name][2]),
                              np.asarray(one.fields[name][0]))
        assert np.array_equal(np.asarray(put.fields[name][:2]),
                              np.asarray(batch.fields[name][:2]))
    mask = np.asarray([True, False, True])
    mixed = ensemble_slot_select(mask, put, batch)
    for name in fields.PROGNOSTIC:
        got = np.asarray(mixed.fields[name])
        assert np.array_equal(got[0], np.asarray(put.fields[name][0]))
        assert np.array_equal(got[1], np.asarray(batch.fields[name][1]))


def test_round_plan_depths_and_validation():
    """round_plan(k) is run()'s ragged-tail machinery made public: the
    full-depth round is `self` (no recompilation), shallower rounds are
    derived plans with the same strategy at k' steps, and out-of-range
    depths fail loudly."""
    plan = compile_dycore(DycoreProgram(grid_shape=(4, 12, 16),
                                        variant="kstep", k_steps=3))
    assert plan.round_plan(3) is plan
    two = plan.round_plan(2)
    assert two.k_steps == 2 and two.variant == plan.variant
    assert two is plan.round_plan(2)                # derived plans cached
    for bad in (0, 4, -1, "2", 2.0):
        with pytest.raises(ValueError, match="round_plan"):
            plan.round_plan(bad)
